"""Seeded property tests: dedup index safety under random histories.

Hypothesis drives random interleavings of deduplicating writes,
overwrites, branches, checkpoint saves and GC rounds across several
blobs on the deterministic Simulator.  A small payload pool forces
heavy cross-blob content collisions, so refcounts are genuinely shared.

The invariants, checked after the history quiesces (GC to fixpoint):

* **no lost bytes** — every surviving (non-retired) version of every
  plain blob reads back byte-identical to a flat oracle replayed from
  the version manager's assigned update order, and every retired
  version answers the typed ``RetiredVersion``: GC with refcounts
  never deletes a page a live version can reach;
* **exact refcounts** — the index's per-page refcount equals the
  number of page-descriptor references from non-retired versions
  (a flat recount over ``update_log``), in both directions: every
  positive oracle count is indexed with that exact count, and every
  indexed page the oracle doesn't see sits at refcount zero (alive
  only through copy-on-write subtree sharing, kept matchable);
* **determinism** — the same seed replays an identical trace digest
  and an identical final refcount map.
"""

from collections import Counter

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    # no skip here: without hypothesis the same invariant check runs
    # over a fixed seed grid instead (see the bottom of this file)
    HAVE_HYPOTHESIS = False

from repro.core import BlobSeerService, RetiredVersion, Simulator, Wire
from repro.core.gc import collect_garbage

PSIZE = 256
POOL = 6            # distinct page contents — small, to force dedup hits


def _page(tag: int) -> bytes:
    return bytes([tag % POOL + 1]) * PSIZE


def _run_history(seed, n_clients, ops_per_client, keep_last):
    """One random concurrent dedup/GC history; returns the service, the
    blob list, the per-version payload map and the trace digest."""
    import numpy as np

    from repro.checkpoint.blobckpt import BlobCheckpointer

    sim = Simulator(seed=seed)
    svc = BlobSeerService(wire=Wire(clock=sim), n_providers=4,
                          n_meta_shards=2, dedup=True)
    setup = svc.client("setup")
    payloads = {}       # (blob, version) -> payload bytes (plain blobs)
    blobs = [setup.create(psize=PSIZE) for _ in range(2)]
    for j, bid in enumerate(blobs):
        vs = setup.append_many(bid, [_page(j), _page(j + 1)])
        payloads[(bid, vs[0])] = _page(j)
        payloads[(bid, vs[1])] = _page(j + 1)
        setup.set_retention(bid, keep_last)

    # one checkpointer: a 4-page model, one dirty page per save; its
    # blob mixes dedup'd leaf writes with never-dedup'd manifest pages
    words = PSIZE // 4
    model = {"w": np.arange(4 * words, dtype=np.int32)}
    ck = BlobCheckpointer(svc.client("ck"), psize=PSIZE, header_pages=2)
    ck.save(model, step=0)
    setup.set_retention(ck.blob_id, keep_last + 1)

    def client_program(ci):
        def prog():
            c = svc.client(f"c{ci:02d}")
            for k in range(ops_per_client):
                tag = (ci * 31 + k * 17 + seed) % 1000
                bid = blobs[(ci + k) % len(blobs)]
                kind = tag % 10
                try:
                    if kind < 4:                   # dedup'd append burst
                        bufs = [_page(tag + j) for j in range((tag % 3) + 1)]
                        vs = c.append_many(bid, bufs)
                        for v, buf in zip(vs, bufs):
                            payloads[(bid, v)] = buf
                    elif kind < 6:                 # aligned overwrite
                        bound = c.get_size(bid, c.get_recent(bid)) // PSIZE
                        if not bound:
                            continue
                        off = ((tag * 13) % bound) * PSIZE
                        v = c.write_many(bid, [(_page(tag), off)])[0]
                        payloads[(bid, v)] = _page(tag)
                    elif kind == 6:                # branch a live version
                        v = c.get_recent(bid)
                        if v > 0:
                            child = c.branch(bid, v)
                            blobs.append(child)
                            c.set_retention(child, keep_last)
                    elif kind == 7 and ci == 0:    # checkpoint a delta
                        model["w"][(tag % 4) * words] = tag
                        ck.save(model, step=k + 1)
                    elif kind == 8:                # GC round, mid-traffic
                        collect_garbage(svc, client=f"gc-c{ci:02d}",
                                        orphan_grace=None)
                    else:
                        v = c.append(bid, _page(tag))   # non-dedup single op
                        payloads[(bid, v)] = _page(tag)
                except RetiredVersion:
                    pass        # recency anchor raced a GC round
            return None

        return prog

    for ci in range(n_clients):
        sim.spawn(client_program(ci), name=f"c{ci:02d}")
    sim.run()

    # quiesce: GC to fixpoint (plus immediate orphan reclaim) so every
    # retired version's refs have been released through the index
    for _ in range(3):
        collect_garbage(svc, client="gc-final", orphan_grace=0.0)
    return svc, blobs, ck, model, payloads, sim.trace_digest()


def _oracle_contents(svc, blobs, payloads):
    """Flat per-version contents replayed from the assigned update order."""
    contents = {}

    def fill(bid):
        if (bid, 0) in contents:
            return
        vm = svc.vm
        chain = vm.lineage(bid)
        base = chain[0][1]
        if len(chain) > 1:
            parent = chain[1][0]
            fill(parent)
            for v in range(0, base + 1):
                contents[(bid, v)] = contents[(parent, v)]
        else:
            contents[(bid, 0)] = b""
        v = base + 1
        while True:
            try:
                rec = svc.vm.update_log(bid, v)
            except Exception:
                break
            prev = contents[(bid, v - 1)]
            buf = bytearray(max(len(prev), rec.offset + rec.size))
            buf[: len(prev)] = prev
            buf[rec.offset: rec.offset + rec.size] = payloads[(bid, v)]
            contents[(bid, v)] = bytes(buf)
            v += 1

    for bid in blobs:
        fill(bid)
    return contents


def _oracle_refcounts(svc, all_blobs):
    """Pd references from non-retired versions, recounted flat."""
    expected = Counter()
    vm = svc.vm
    for bid in all_blobs:
        base = vm.lineage(bid)[0][1]
        retired = vm.retired_versions(bid)
        v = base + 1
        while True:
            try:
                rec = vm.update_log(bid, v)
            except Exception:
                break
            if v not in retired:
                for pid, _rel, _provs, _length in rec.pd:
                    expected[pid] += 1
            v += 1
    return expected


def _check_history(seed, n_clients, keep_last):
    import numpy as np

    svc, blobs, ck, model, payloads, digest = _run_history(
        seed, n_clients, ops_per_client=6, keep_last=keep_last)

    # -- no lost bytes: surviving versions read back exactly; retired
    # versions answer the typed error
    reader = svc.client("oracle-reader")
    contents = _oracle_contents(svc, blobs, payloads)
    for bid in blobs:
        base = svc.vm.lineage(bid)[0][1]
        retired = svc.vm.retired_versions(bid)
        v = base + 1
        while (bid, v) in contents:
            want = contents[(bid, v)]
            if v in retired:
                with pytest.raises(RetiredVersion):
                    reader.read(bid, v, 0, max(len(want), 1))
            elif want:
                assert reader.read(bid, v, 0, len(want)) == want, \
                    f"{bid} v{v} lost bytes (seed={seed})"
            v += 1

    # -- the checkpointer's state survives the whole history too
    got = ck.restore({"w": np.zeros_like(model["w"])})
    assert np.array_equal(got["w"], model["w"])

    # -- exact refcounts vs the flat oracle, both directions
    expected = _oracle_refcounts(svc, blobs + [ck.blob_id])
    indexed = svc.dedup_index.indexed_pages()
    for pid, cnt in expected.items():
        if pid in indexed:
            assert indexed[pid] == cnt, f"{pid}: rc {indexed[pid]} != {cnt}"
    for pid, rc in indexed.items():
        assert rc == expected.get(pid, 0), \
            f"{pid}: rc {rc} but oracle counts {expected.get(pid, 0)}"

    # -- determinism: same seed, same trace, same final index shape
    # (raw page ids come from a process-global counter, so the replay's
    # ids differ; the refcount multiset must not)
    svc2, _b2, _ck2, _m2, _p2, digest2 = _run_history(
        seed, n_clients, ops_per_client=6, keep_last=keep_last)
    indexed2 = svc2.dedup_index.indexed_pages()
    assert digest == digest2
    assert sorted(indexed2.values()) == sorted(indexed.values())


_FIXED_GRID = [(0, 2, 1), (7, 3, 2), (123, 4, 1), (999, 4, 3)]

if HAVE_HYPOTHESIS:

    @settings(max_examples=6, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=2**16),
        n_clients=st.integers(min_value=2, max_value=4),
        keep_last=st.integers(min_value=1, max_value=3),
    )
    def test_dedup_gc_random_interleavings(seed, n_clients, keep_last):
        _check_history(seed, n_clients, keep_last)

else:

    @pytest.mark.parametrize("seed,n_clients,keep_last", _FIXED_GRID)
    def test_dedup_gc_random_interleavings(seed, n_clients, keep_last):
        _check_history(seed, n_clients, keep_last)
