"""Concurrency: total ordering, atomicity, lock-free data-path claims."""

import random
import threading

import pytest

from repro.core import BlobSeerService


def test_concurrent_appends_total_order_and_atomicity():
    svc = BlobSeerService(n_providers=8, n_meta_shards=4)
    c0 = svc.client("main")
    bid = c0.create(psize=32)
    N_T, N_A = 6, 8
    results = {}
    errs = []

    def worker(tid):
        try:
            c = svc.client(f"w{tid}")
            for i in range(N_A):
                payload = bytes([tid + 1]) * random.Random(tid * 100 + i).randint(5, 90)
                v = c.append(bid, payload)
                results[(tid, i)] = (v, payload)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(N_T)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert not errs
    versions = sorted(v for v, _ in results.values())
    assert versions == list(range(1, N_T * N_A + 1))
    c0.sync(bid, versions[-1], timeout=10)
    offset = 0
    for v, payload in sorted(results.values()):
        assert c0.read(bid, v, offset, len(payload)) == payload
        offset += len(payload)
    assert c0.get_size(bid, versions[-1]) == offset


def test_concurrent_writers_and_readers():
    svc = BlobSeerService(n_providers=8, n_meta_shards=4)
    c = svc.client()
    bid = c.create(psize=16)
    c.write(bid, b"\x00" * 512, 0)
    stop = threading.Event()
    errs = []

    def writer(tid):
        try:
            cl = svc.client(f"w{tid}")
            for i in range(10):
                off = random.Random(tid * 31 + i).randint(0, 400)
                cl.write(bid, bytes([tid + 1]) * 30, off)
        except Exception as e:
            errs.append(e)

    def reader():
        try:
            cl = svc.client("r")
            while not stop.is_set():
                v = cl.get_recent(bid)
                if v:
                    data = cl.read(bid, v, 0, cl.get_size(bid, v))
                    assert len(data) == 512
        except Exception as e:
            errs.append(e)

    ws = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    r = threading.Thread(target=reader)
    r.start()
    [w.start() for w in ws]
    [w.join() for w in ws]
    stop.set()
    r.join()
    assert not errs
    assert c.get_recent(bid) == 1 + 4 * 10


def test_reader_never_sees_partial_update():
    """Atomicity: every published snapshot is internally consistent —
    an update's bytes appear all-or-nothing."""
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=8)
    c.write(bid, b"\x00" * 256, 0)
    errs = []
    stop = threading.Event()

    def writer():
        cl = svc.client("w")
        for i in range(1, 30):
            cl.write(bid, bytes([i]) * 64, 64)  # same range, 8 pages

    def reader():
        cl = svc.client("r")
        while not stop.is_set():
            v = cl.get_recent(bid)
            data = cl.read(bid, v, 64, 64)
            if len(set(data)) != 1:
                errs.append(f"torn read at v{v}: {set(data)}")

    r = threading.Thread(target=reader)
    w = threading.Thread(target=writer)
    r.start()
    w.start()
    w.join()
    stop.set()
    r.join()
    assert not errs, errs[:3]


def test_sync_blocks_until_published():
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    done = []

    def late_writer():
        cw = svc.client("late")
        cw.append(bid, b"x" * 64)
        done.append(True)

    t = threading.Thread(target=late_writer)
    t.start()
    c.sync(bid, 1, timeout=10)
    t.join()
    assert done and c.get_recent(bid) >= 1
    with pytest.raises(TimeoutError):
        c.sync(bid, 99, timeout=0.05)
