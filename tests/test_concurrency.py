"""Concurrency: total ordering, atomicity, lock-free data-path claims.

Rewritten on the deterministic virtual-time harness (core/sim.py):
the assertions that used to run on 6 real Python threads now run at
64+ simulated clients, every interleaving replayable from the seed.
A thread-based smoke test remains for the default wall-clock backend.
"""

import random
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is unavailable
    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

from repro.core import BlobSeerService, Simulator, Wire
from repro.core.scenarios import run_scenario


def _sim_service(seed=0, **kw):
    sim = Simulator(seed=seed)
    kw.setdefault("n_providers", 8)
    kw.setdefault("n_meta_shards", 4)
    svc = BlobSeerService(wire=Wire(clock=sim), **kw)
    return sim, svc


def test_concurrent_appends_total_order_and_atomicity_64_clients():
    """The seed test's assertions, at 64 simulated appenders."""
    sim, svc = _sim_service(seed=11)
    c0 = svc.client("main")
    bid = c0.create(psize=32)
    N_T, N_A = 64, 3
    results = {}

    def worker(tid):
        def prog():
            c = svc.client(f"w{tid:03d}")
            for i in range(N_A):
                payload = bytes([tid % 250 + 1]) * random.Random(
                    tid * 100 + i).randint(5, 90)
                v = c.append(bid, payload)
                results[(tid, i)] = (v, payload)
        return prog

    for t in range(N_T):
        sim.spawn(worker(t), name=f"w{t:03d}")
    sim.run()
    versions = sorted(v for v, _ in results.values())
    assert versions == list(range(1, N_T * N_A + 1))
    # atomicity + total order: every update's bytes sit exactly at the
    # offset implied by the version order, in every published snapshot
    offset = 0
    for v, payload in sorted(results.values()):
        assert c0.read(bid, v, offset, len(payload)) == payload
        offset += len(payload)
    assert c0.get_size(bid, versions[-1]) == offset


def test_concurrent_writers_and_readers_64_clients():
    sim, svc = _sim_service(seed=5)
    c = svc.client("setup")
    bid = c.create(psize=16)
    c.write(bid, b"\x00" * 512, 0)
    n_writers, n_readers = 32, 32

    def writer(tid):
        def prog():
            cl = svc.client(f"w{tid:03d}")
            for i in range(4):
                off = random.Random(tid * 31 + i).randint(0, 400)
                cl.write(bid, bytes([tid % 250 + 1]) * 30, off)
        return prog

    def reader(tid):
        def prog():
            cl = svc.client(f"r{tid:03d}")
            for _ in range(4):
                v = cl.get_recent(bid)
                if v:
                    assert len(cl.read(bid, v, 0, cl.get_size(bid, v))) == 512
        return prog

    for t in range(n_writers):
        sim.spawn(writer(t), name=f"w{t:03d}")
    for t in range(n_readers):
        sim.spawn(reader(t), name=f"r{t:03d}")
    sim.run()
    assert c.get_recent(bid) == 1 + n_writers * 4


def test_reader_never_sees_partial_update_at_scale():
    """Atomicity: an update's bytes appear all-or-nothing, checked by 16
    readers racing 16 writers over the same 8-page range."""
    sim, svc = _sim_service(seed=3, n_providers=4, n_meta_shards=2)
    c = svc.client("setup")
    bid = c.create(psize=8)
    c.write(bid, b"\x00" * 256, 0)
    torn = []

    def writer(tid):
        def prog():
            cl = svc.client(f"w{tid:03d}")
            for i in range(3):
                cl.write(bid, bytes([((tid * 3 + i) % 250) + 1]) * 64, 64)
        return prog

    def reader(tid):
        def prog():
            cl = svc.client(f"r{tid:03d}")
            for _ in range(6):
                v = cl.get_recent(bid)
                data = cl.read(bid, v, 64, 64)
                if len(set(data)) != 1:
                    torn.append(f"torn read at v{v}: {set(data)}")
        return prog

    for t in range(16):
        sim.spawn(writer(t), name=f"w{t:03d}")
        sim.spawn(reader(t), name=f"r{t:03d}")
    sim.run()
    assert not torn, torn[:3]


def test_sync_blocks_until_published_virtual_time():
    """SYNC blocks in virtual time; timeouts fire on the virtual clock
    without wall-clock sleeping."""
    sim, svc = _sim_service(seed=2, n_providers=2, n_meta_shards=2)
    c0 = svc.client("setup")
    bid = c0.create(psize=16)
    order = []

    def late_writer():
        sim.sleep(5.0)  # five *virtual* seconds
        svc.client("late").append(bid, b"x" * 64)
        order.append("published")

    def syncer():
        svc.client("s").sync(bid, 1, timeout=60.0)
        order.append("sync-returned")
        assert sim.now() >= 5.0

    def too_impatient():
        with pytest.raises(TimeoutError):
            svc.client("t").sync(bid, 99, timeout=1.0)
        order.append("timeout")

    sim.spawn(late_writer, name="w")
    sim.spawn(syncer, name="s")
    sim.spawn(too_impatient, name="t")
    sim.run()
    assert order == ["timeout", "published", "sync-returned"]


def test_sync_blocks_until_published_wall_backend():
    """The default threads backend still works (seed test, unchanged)."""
    svc = BlobSeerService(n_providers=2, n_meta_shards=2)
    c = svc.client()
    bid = c.create(psize=16)
    done = []

    def late_writer():
        cw = svc.client("late")
        cw.append(bid, b"x" * 64)
        done.append(True)

    t = threading.Thread(target=late_writer)
    t.start()
    c.sync(bid, 1, timeout=10)
    t.join()
    assert done and c.get_recent(bid) >= 1
    with pytest.raises(TimeoutError):
        c.sync(bid, 99, timeout=0.05)


# ---------------------------------------------------------------------------
# Seeded-interleaving properties
# ---------------------------------------------------------------------------


def test_same_seed_identical_trace_and_content():
    a = run_scenario("appenders", 24, seed=9, n_providers=6, n_meta_shards=3)
    b = run_scenario("appenders", 24, seed=9, n_providers=6, n_meta_shards=3)
    assert a.trace_digest == b.trace_digest
    assert a.makespan == b.makespan
    assert a.rpc == b.rpc


@pytest.mark.exploration
@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**31))
def test_disjoint_writes_schedule_independent(seed):
    """Published-version reads are identical across schedules: writers to
    disjoint ranges commute, so the *final* snapshot's content must not
    depend on the interleaving the seed produced."""
    from repro.core.scenarios import SCENARIOS, build_env

    contents = []
    for s in (seed % 1009, (seed * 7 + 3) % 1009):
        env = build_env(8, seed=s, n_providers=4, n_meta_shards=2,
                        psize=512, chunk_pages=2, ops_per_client=2,
                        scenario="writers")
        spec = SCENARIOS["writers"]
        spec.setup(env)
        for i in range(8):
            env.sim.spawn(spec.program(env, i), name=f"w{i:03d}")
        env.sim.run()
        c = env.client("check")
        v = c.get_recent(env.blob)
        assert v == 8 + 8 * 2  # setup appends + every write published
        contents.append(c.read(env.blob, v, 0, c.get_size(env.blob, v)))
    assert contents[0] == contents[1]


@pytest.mark.exploration
@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2**31))
def test_append_total_order_any_schedule(seed):
    """Whatever the interleaving, versions are a contiguous total order
    and every snapshot's bytes match the version-ordered payloads."""
    from repro.core.scenarios import SCENARIOS, build_env

    env = build_env(10, seed=seed % 99991, n_providers=4, n_meta_shards=2,
                    psize=256, chunk_pages=1, ops_per_client=2,
                    scenario="appenders")
    spec = SCENARIOS["appenders"]
    spec.setup(env)
    for i in range(10):
        env.sim.spawn(spec.program(env, i), name=f"a{i:03d}")
    env.sim.run()
    results = env.sim.results()
    versions = sorted(
        v for r in results.values() for v in r["versions"]
    )
    assert versions == list(range(1, 21))
    c = env.client("check")
    by_version = {
        v: bytes([i % 251 + 1]) * env.chunk
        for i, (name, r) in enumerate(sorted(results.items()))
        for v in r["versions"]
    }
    offset = 0
    for v in versions:
        assert c.read(env.blob, v, offset, env.chunk) == by_version[v]
        offset += env.chunk
