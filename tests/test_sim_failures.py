"""Failure injection at scheduled virtual times.

A provider or metadata shard goes down mid-scenario at an exact virtual
instant (something wall-clock thread tests can never do reproducibly);
readers must fail over to surviving replicas per the ARCHITECTURE.md
invariants — batched paths re-route only the affected requests, and
``EndpointDown`` surfaces only when every replica of a page/key is gone.
"""

import pytest

from repro.core import BlobSeerService, EndpointDown, Simulator, Wire
from repro.core.scenarios import run_scenario


def test_readers_fail_over_when_provider_dies_mid_scenario():
    r = run_scenario(
        "readers", 32, seed=4,
        n_providers=8, n_meta_shards=4, data_replication=2,
        failures=[(0.004, "prov-0003")],   # mid-read-phase, virtual time
    )
    assert not r.errors, r.errors
    assert r.ops == 32 * 2                 # every read served via failover
    assert r.client_results["chaos-prov-0003"]["killed"] == "prov-0003"


def test_readers_fail_over_when_metadata_shard_dies():
    r = run_scenario(
        "readers", 32, seed=4,
        n_providers=8, n_meta_shards=4, meta_replication=2,
        failures=[(0.004, "meta-0001")],
    )
    assert not r.errors, r.errors
    assert r.ops == 32 * 2


def test_unreplicated_scenario_surfaces_endpoint_down():
    r = run_scenario(
        "readers", 16, seed=4,
        n_providers=4, n_meta_shards=2, data_replication=1,
        failures=[(0.002, "prov-0001")],
        raise_errors=False,
    )
    assert any("EndpointDown" in e for e in r.errors.values()), r.errors


def test_failure_schedule_is_deterministic():
    kw = dict(n_providers=8, n_meta_shards=4, data_replication=2,
              failures=[(0.004, "prov-0003")])
    a = run_scenario("readers", 24, seed=9, **kw)
    b = run_scenario("readers", 24, seed=9, **kw)
    assert a.trace_digest == b.trace_digest
    assert a.rpc == b.rpc


def test_appenders_survive_provider_death_with_replication():
    """Writes keep publishing after a provider dies: store_page drops the
    dead replica, total order stays contiguous."""
    r = run_scenario(
        "appenders", 24, seed=2,
        n_providers=6, n_meta_shards=3, data_replication=2,
        failures=[(0.003, "prov-0002")],
    )
    assert not r.errors, r.errors
    versions = sorted(
        v for res in r.client_results.values()
        if isinstance(res, dict) for v in res.get("versions", ())
    )
    assert versions == list(range(1, 24 * 2 + 1))


def test_heartbeat_detection_in_virtual_time():
    """Heartbeats age on the virtual clock: a maintenance task detects a
    silent provider deterministically at its scheduled sweep instant."""
    sim = Simulator(seed=0)
    svc = BlobSeerService(n_providers=3, n_meta_shards=2,
                          wire=Wire(clock=sim), heartbeat_timeout=1.0)
    dead = []

    def beat(pid):
        def prog():
            for _ in range(8):
                sim.sleep(0.5)
                svc.pm.get(pid).heartbeat()
        return prog

    def sweeper():
        sim.sleep(2.5)
        dead.extend(svc.pm.check_heartbeats())

    sim.spawn(beat("prov-0000"), name="beat-0")
    sim.spawn(beat("prov-0002"), name="beat-2")
    sim.spawn(sweeper, name="sweeper")   # prov-0001 never beats
    sim.run()
    assert dead == ["prov-0001"]
    assert svc.pm.n_alive() == 2


def test_wal_replayed_stall_detected_under_virtual_clock(tmp_path):
    """A WAL-replayed incomplete update must look stalled on the
    *recovering* VM's clock: with the wall-time default stamp, virtual
    now() minus monotonic would be hugely negative and recovery would
    never fire."""
    from repro.core.version_manager import VersionManager

    wal = str(tmp_path / "vm.wal")
    vm = VersionManager(wal_path=wal)
    bid = vm.create(64, client="c")
    vm.assign_version(bid, None, 64, client="c")  # writer dies here

    sim = Simulator(seed=0)
    vm2 = VersionManager.recover_from_wal(wal, wire=Wire(clock=sim))
    sim.spawn(lambda: sim.sleep(1.0), name="tick")  # virtual time passes
    sim.run()
    stalled = vm2.find_stalled(0.5)
    assert [(b, r.version) for b, r in stalled] == [(bid, 1)]


def test_revived_provider_rejoins_and_serves():
    sim = Simulator(seed=1)
    svc = BlobSeerService(n_providers=4, n_meta_shards=2,
                          wire=Wire(clock=sim), data_replication=2)
    c0 = svc.client("setup")
    bid = c0.create(psize=64)
    v = c0.write(bid, bytes(range(256)), 0)
    out = {}

    def chaos():
        svc.kill_provider("prov-0001")
        sim.sleep(0.01)
        svc.revive_provider("prov-0001")

    def reader():
        c = svc.client("r")
        out["during"] = c.read(bid, v, 0, 256)   # provider down: failover
        sim.sleep(0.02)
        out["after"] = c.read(bid, v, 0, 256)    # provider back
        return True

    sim.spawn(chaos, name="chaos")
    sim.spawn(reader, name="r")
    sim.run()
    assert out["during"] == bytes(range(256))
    assert out["after"] == bytes(range(256))
