"""BlobSeer checkpointing: incremental COW, atomic publish, branch, resume."""

import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import BlobCheckpointer
from repro.core import BlobSeerService
from repro.data import ByteTokenizer, CorpusWriter, ShardedReader


@pytest.fixture
def ckpt_env():
    svc = BlobSeerService(n_providers=6, n_meta_shards=4)
    c = svc.client()
    return svc, c


def _state(seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": scale * jax.random.normal(k, (600,)),
                   "frozen": jnp.ones((256,), jnp.float32)},
        "step": jnp.asarray(seed, jnp.int32),
    }


def test_save_restore_roundtrip(ckpt_env):
    svc, c = ckpt_env
    ck = BlobCheckpointer(c, psize=256, header_pages=8)
    s = _state(1)
    stats = ck.save(s, step=1)
    assert stats.version >= 1
    got = ck.restore(jax.eval_shape(lambda: s))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incremental_save_shares_unchanged_pages(ckpt_env):
    svc, c = ckpt_env
    ck = BlobCheckpointer(c, psize=256, header_pages=8)
    s1 = _state(1)
    st1 = ck.save(s1, step=1)
    s2 = dict(s1, step=jnp.asarray(2, jnp.int32))  # only 'step' changes
    st2 = ck.save(s2, step=2)
    assert st2.pages_written < st1.pages_total // 4
    assert st2.sharing_fraction > 0.5


def test_old_checkpoints_remain_readable(ckpt_env):
    svc, c = ckpt_env
    ck = BlobCheckpointer(c, psize=256, header_pages=8)
    versions = {}
    for step in range(1, 4):
        s = _state(step, scale=float(step))
        stats = ck.save(s, step=step)
        versions[step] = (stats.version, s)
    for step, (v, want) in versions.items():
        got, mani = ck.restore(jax.eval_shape(lambda: want), version=v,
                               with_manifest=True)
        assert mani["step"] == step
        np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                                   np.asarray(want["params"]["w"]))


def test_branch_forks_lineage(ckpt_env):
    svc, c = ckpt_env
    ck = BlobCheckpointer(c, psize=256, header_pages=8)
    s1 = _state(1)
    st1 = ck.save(s1, step=1)
    child = ck.branch(st1.version)
    sb = _state(9, scale=3.0)
    child.save(sb, step=9)
    s2 = _state(2, scale=2.0)
    ck.save(s2, step=2)
    got_b = child.restore(jax.eval_shape(lambda: sb))
    got_2 = ck.restore(jax.eval_shape(lambda: s2))
    np.testing.assert_allclose(np.asarray(got_b["params"]["w"]),
                               np.asarray(sb["params"]["w"]))
    np.testing.assert_allclose(np.asarray(got_2["params"]["w"]),
                               np.asarray(s2["params"]["w"]))


def test_reader_mid_save_sees_consistent_checkpoint(ckpt_env):
    """Atomic publication: GET_RECENT during a save never yields a torn
    checkpoint — restores resolve either the old or the new manifest."""
    svc, c = ckpt_env
    ck = BlobCheckpointer(c, psize=128, header_pages=8)
    shapes = jax.eval_shape(lambda: _state(0))
    ck.save(_state(1, scale=1.0), step=1)
    errs = []
    stop = threading.Event()

    def reader():
        rc = svc.client("reader")
        rck = BlobCheckpointer(rc, ck.blob_id, header_pages=8)
        while not stop.is_set():
            try:
                got, mani = rck.restore(shapes, with_manifest=True)
                w = np.asarray(got["params"]["w"])
                expect = np.asarray(_state(mani["step"],
                                           scale=float(mani["step"])) ["params"]["w"])
                if not np.allclose(w, expect):
                    errs.append(f"torn checkpoint at step {mani['step']}")
            except Exception as e:
                errs.append(repr(e))

    t = threading.Thread(target=reader)
    t.start()
    for step in range(2, 6):
        ck.save(_state(step, scale=float(step)), step=step)
    stop.set()
    t.join()
    assert not errs, errs[:3]


def test_restart_resumes_delta_detection(ckpt_env):
    svc, c = ckpt_env
    ck = BlobCheckpointer(c, psize=256, header_pages=8)
    s = _state(1)
    ck.save(s, step=1)
    ck2 = BlobCheckpointer(c, ck.blob_id, header_pages=8)
    ck2.load_digest_cache()
    stats = ck2.save(s, step=2)       # identical content
    assert stats.pages_written == 0


def test_manifest_carries_extra_state(ckpt_env):
    svc, c = ckpt_env
    ck = BlobCheckpointer(c, psize=256, header_pages=8)
    ck.save(_state(1), step=1, extra={"reader": {"version": 3, "position": 77,
                                                 "shard": 0, "n_shards": 2}})
    _, mani = ck.restore(jax.eval_shape(lambda: _state(1)), with_manifest=True)
    assert mani["extra"]["reader"]["position"] == 77


def test_pipeline_reader_deterministic_resume(ckpt_env):
    svc, c = ckpt_env
    w = CorpusWriter(c, psize=128)
    tok = ByteTokenizer()
    for i in range(30):
        w.append_tokens(tok.encode(f"doc {i} " + "lorem ipsum " * (i % 7 + 1)))
    r = ShardedReader(c, w.blob_id, batch=2, seq_len=16)
    _ = r.next_batch()
    st = r.state_dict()
    want = [r.next_batch() for _ in range(3)]
    r2 = ShardedReader(c, w.blob_id, batch=2, seq_len=16, state=st)
    got = [r2.next_batch() for _ in range(3)]
    for (a1, b1), (a2, b2) in zip(want, got):
        np.testing.assert_array_equal(a1, a2)
        np.testing.assert_array_equal(b1, b2)


def test_concurrent_ingestion_does_not_disturb_pinned_reader(ckpt_env):
    svc, c = ckpt_env
    w = CorpusWriter(c, psize=128)
    tok = ByteTokenizer()
    for i in range(20):
        w.append_tokens(tok.encode(f"base doc {i} " + "abc " * 20))
    r = ShardedReader(c, w.blob_id, batch=2, seq_len=8)
    pinned = r.state.version
    first = r.next_batch()
    stop = threading.Event()

    def ingest():
        cw = CorpusWriter(svc.client("ingest"), w.blob_id)
        i = 0
        while not stop.is_set():
            cw.append_tokens(tok.encode(f"new doc {i}"))
            i += 1

    t = threading.Thread(target=ingest)
    t.start()
    r_again = ShardedReader(c, w.blob_id, batch=2, seq_len=8,
                            state=dict(version=pinned, position=0,
                                       shard=0, n_shards=1))
    again = r_again.next_batch()
    stop.set()
    t.join()
    np.testing.assert_array_equal(first[0], again[0])


def test_rolling_pin_taken_before_commit_survives_gc_race(ckpt_env):
    """Worst-case interleaving: a retention GC round (keep-last-1) fires
    after every single write RPC of save(). The rolling manifest pin is
    taken while the manifest snapshot is still the newest published
    version — before the commit pointer write — so no round can retire
    the manifest of a just-committed checkpoint."""
    from repro.core import collect_garbage

    svc, c = ckpt_env
    ck = BlobCheckpointer(c, psize=256, header_pages=8)
    c.set_retention(ck.blob_id, keep_last=1)
    orig_write = c.write

    def write_then_gc(bid, buf, off):
        v = orig_write(bid, buf, off)
        collect_garbage(svc, orphan_grace=None)
        return v

    c.write = write_then_gc
    try:
        s = _state(1)
        ck.save(s, step=1)
        got = ck.restore(jax.eval_shape(lambda: s))
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(s)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the next save rolls the pin forward under the same race
        s2 = dict(s, step=jnp.asarray(2, jnp.int32))
        ck.save(s2, step=2)
        got2 = ck.restore(jax.eval_shape(lambda: s2))
        np.testing.assert_array_equal(np.asarray(got2["step"]), 2)
    finally:
        c.write = orig_write


def test_failed_commit_releases_fresh_pin(ckpt_env):
    """If the commit-pointer write fails after the rolling pin was
    taken, the pin is released — a failed save() must not leak an
    untimed lease that excludes its manifest snapshot from GC forever."""
    svc, c = ckpt_env
    ck = BlobCheckpointer(c, psize=256, header_pages=8)
    s = _state(1)
    ck.save(s, step=1)
    base = len(svc.vm.pins())
    orig_write = c.write

    def fail_commit(bid, buf, off):
        if off == 0 and len(buf) == 9:  # the commit-pointer record
            raise RuntimeError("injected commit failure")
        return orig_write(bid, buf, off)

    c.write = fail_commit
    try:
        with pytest.raises(RuntimeError):
            ck.save(_state(2, scale=2.0), step=2)
    finally:
        c.write = orig_write
    assert len(svc.vm.pins()) == base  # no orphan lease
    ck.save(_state(3, scale=3.0), step=3)  # next save recovers cleanly
    assert len(svc.vm.pins()) == base
