"""Linearizability-style checker for version-manager histories.

Concurrent APPEND / WRITE / GET_RECENT histories generated on the
virtual-time harness must admit a total order consistent with the
assigned versions (the paper's §4.3 total-ordering claim):

* versions form a contiguous total order 1..K,
* the version order is a linear extension of the real-time interval
  order — if update A responded before update B was invoked, then
  version(A) < version(B),
* GET_RECENT is monotone in real time (publication never goes
  backwards) and never returns a version from the future (one whose
  update had not even been invoked when the get responded),
* every returned recent version is fully readable (atomicity: the
  snapshot resolves completely),
* NOTIFY deliveries (the subscription plane) are causal and ordered:
  a delivered version's update was invoked before the delivery
  responded, each watcher's delivery stream is strictly monotone (no
  duplicate, no regression), nothing at or below the watch's
  ``from_version`` floor is delivered, and a watcher's own poll after
  a delivery responded observes at least the delivered version (push
  never outruns what poll could see).

Virtual timestamps come from ``Simulator.now()``, so the intervals are
exact — no wall-clock jitter — and every counterexample is replayable
from the seed.
"""

from dataclasses import dataclass
from typing import List

import pytest

from repro.core import BlobSeerService, Simulator, Wire


@dataclass(frozen=True)
class Op:
    client: str
    kind: str            # "append" | "write" | "get_recent"
    #                    # | "watch" | "deliver"
    invoke: float
    respond: float
    result: int          # version assigned / observed / delivered;
    #                    # for "watch": the from_version floor
    size: int = 0


def check_history(hist: List[Op]) -> None:
    updates = [op for op in hist if op.kind in ("append", "write")]
    gets = [op for op in hist if op.kind == "get_recent"]

    # total order: contiguous versions, one per update
    versions = sorted(op.result for op in updates)
    assert versions == list(range(1, len(updates) + 1)), versions

    # version order extends real-time precedence between updates
    for a in updates:
        for b in updates:
            if a.respond < b.invoke:
                assert a.result < b.result, (
                    f"{a.client} v{a.result} responded at {a.respond:.6f} "
                    f"before {b.client} v{b.result} invoked at {b.invoke:.6f} "
                    f"but got the later version"
                )

    # GET_RECENT: monotone in real time
    for a in gets:
        for b in gets:
            if a.respond < b.invoke:
                assert a.result <= b.result, (
                    f"recent version went backwards: {a.result} then {b.result}"
                )

    # GET_RECENT: never from the future
    assigned = {op.result: op for op in updates}
    for g in gets:
        if g.result > 0:
            u = assigned.get(g.result)
            assert u is not None, f"observed unassigned version {g.result}"
            assert u.invoke <= g.respond, (
                f"observed v{g.result} before its update was invoked"
            )

    # NOTIFY: a delivered version was published before the delivery —
    # its update must at least have been invoked by the respond instant
    delivers = [op for op in hist if op.kind == "deliver"]
    for d in delivers:
        u = assigned.get(d.result)
        assert u is not None, f"delivered unassigned version {d.result}"
        assert u.invoke <= d.respond, (
            f"{d.client} was notified of v{d.result} before its update "
            f"was invoked"
        )

    # NOTIFY: per-watcher delivery order is strictly monotone — a
    # later delivery carries a strictly larger version (no duplicate,
    # no regression)
    for a in delivers:
        for b in delivers:
            if a.client == b.client and a.respond < b.invoke:
                assert a.result < b.result, (
                    f"{a.client} delivery went backwards or repeated: "
                    f"v{a.result} then v{b.result}"
                )

    # NOTIFY: nothing at or below the watch's from_version floor
    floors = {op.client: op for op in hist if op.kind == "watch"}
    for d in delivers:
        w = floors.get(d.client)
        if w is not None:
            assert d.result > w.result, (
                f"{d.client} delivered v{d.result} at or below its "
                f"watch floor v{w.result}"
            )

    # NOTIFY vs poll: once a delivery of v responded, the watcher's own
    # later GET_RECENT must observe at least v — push never claims a
    # version the watcher's poll could not yet see
    for d in delivers:
        for g in gets:
            if g.client == d.client and d.respond < g.invoke:
                assert g.result >= d.result, (
                    f"{d.client} poll lagged push: delivered v{d.result} "
                    f"but a later get_recent returned v{g.result}"
                )


def _run_history(seed: int, n_updaters: int = 24, n_observers: int = 8,
                 ops_each: int = 3, n_watchers: int = 4) -> List[Op]:
    sim = Simulator(seed=seed)
    svc = BlobSeerService(n_providers=6, n_meta_shards=3,
                          wire=Wire(clock=sim))
    setup = svc.client("setup")
    bid = setup.create(psize=64)
    setup.append(bid, b"\x00" * 128)  # v1 so early readers have something
    hist: List[Op] = []

    def updater(i):
        def prog():
            c = svc.client(f"u{i:03d}")
            for k in range(ops_each):
                inv = sim.now()
                if (i + k) % 3 == 0:
                    v = c.write(bid, bytes([i % 250 + 1]) * 64, 0)
                    kind = "write"
                else:
                    v = c.append(bid, bytes([i % 250 + 1]) * 64)
                    kind = "append"
                hist.append(Op(f"u{i:03d}", kind, inv, sim.now(), v, 64))
        return prog

    def observer(i):
        def prog():
            c = svc.client(f"o{i:03d}")
            for _ in range(ops_each):
                inv = sim.now()
                v = c.get_recent(bid)
                hist.append(Op(f"o{i:03d}", "get_recent", inv, sim.now(), v))
                if v:
                    # atomicity: the observed snapshot resolves completely
                    size = c.get_size(bid, v)
                    assert len(c.read(bid, v, 0, size)) == size
        return prog

    def watcher(i):
        def prog():
            c = svc.client(f"n{i:03d}")
            inv = sim.now()
            wid = c.watch(bid, from_version=0)
            hist.append(Op(f"n{i:03d}", "watch", inv, sim.now(), 0))
            for _ in range(ops_each * 6):
                sim.sleep(0.002)
                inv = sim.now()
                for v in c.poll_notifications(wid):
                    hist.append(Op(f"n{i:03d}", "deliver", inv, sim.now(), v))
                inv = sim.now()
                g = c.get_recent(bid)
                hist.append(Op(f"n{i:03d}", "get_recent", inv, sim.now(), g))
            c.unwatch(wid)
        return prog

    for i in range(n_updaters):
        sim.spawn(updater(i), name=f"u{i:03d}")
    for i in range(n_observers):
        sim.spawn(observer(i), name=f"o{i:03d}")
    for i in range(n_watchers):
        sim.spawn(watcher(i), name=f"n{i:03d}")
    sim.run()
    # drop the setup append from the contiguity check's expectations by
    # folding it in as an update that happened before everything
    hist.append(Op("setup", "append", -1.0, -0.5, 1, 128))
    return hist


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_concurrent_history_linearizes_to_version_order(seed):
    check_history(_run_history(seed))


def test_checker_rejects_precedence_violation():
    """The checker itself must catch a non-linearizable history."""
    bad = [
        Op("a", "append", 0.0, 1.0, 2),   # responded first, later version
        Op("b", "append", 2.0, 3.0, 1),   # invoked after a responded
    ]
    with pytest.raises(AssertionError, match="later version"):
        check_history(bad)


def test_checker_rejects_time_travelling_get_recent():
    bad = [
        Op("a", "append", 5.0, 6.0, 1),
        Op("o", "get_recent", 0.0, 0.5, 1),  # observed before invoked
    ]
    with pytest.raises(AssertionError, match="before its update"):
        check_history(bad)


def test_checker_rejects_nonmonotone_get_recent():
    bad = [
        Op("a", "append", 0.0, 0.1, 1),
        Op("b", "append", 0.0, 0.2, 2),
        Op("o1", "get_recent", 1.0, 1.1, 2),
        Op("o2", "get_recent", 2.0, 2.1, 1),  # goes backwards
    ]
    with pytest.raises(AssertionError, match="backwards"):
        check_history(bad)


def test_checker_rejects_delivery_before_publication():
    bad = [
        Op("a", "append", 5.0, 6.0, 1),
        Op("w", "deliver", 0.0, 0.5, 1),  # delivered before invoked
    ]
    with pytest.raises(AssertionError, match="notified of v1 before"):
        check_history(bad)


def test_checker_rejects_unassigned_delivery():
    bad = [
        Op("a", "append", 0.0, 0.1, 1),
        Op("w", "deliver", 1.0, 1.1, 7),  # no such update
    ]
    with pytest.raises(AssertionError, match="delivered unassigned"):
        check_history(bad)


def test_checker_rejects_duplicate_delivery():
    bad = [
        Op("a", "append", 0.0, 0.1, 1),
        Op("b", "append", 0.0, 0.2, 2),
        Op("w", "deliver", 1.0, 1.1, 2),
        Op("w", "deliver", 2.0, 2.1, 2),  # repeated
    ]
    with pytest.raises(AssertionError, match="backwards or repeated"):
        check_history(bad)


def test_checker_rejects_regressing_delivery():
    bad = [
        Op("a", "append", 0.0, 0.1, 1),
        Op("b", "append", 0.0, 0.2, 2),
        Op("w", "deliver", 1.0, 1.1, 2),
        Op("w", "deliver", 2.0, 2.1, 1),  # went backwards
    ]
    with pytest.raises(AssertionError, match="backwards or repeated"):
        check_history(bad)


def test_checker_rejects_delivery_below_watch_floor():
    bad = [
        Op("a", "append", 0.0, 0.1, 1),
        Op("b", "append", 0.0, 0.2, 2),
        Op("w", "watch", 0.5, 0.6, 2),    # from_version=2
        Op("w", "deliver", 1.0, 1.1, 2),  # at the floor: must be above
    ]
    with pytest.raises(AssertionError, match="watch floor"):
        check_history(bad)


def test_checker_rejects_push_ahead_of_poll():
    bad = [
        Op("a", "append", 0.0, 0.1, 1),
        Op("b", "append", 0.0, 0.2, 2),
        Op("w", "deliver", 1.0, 1.1, 2),
        Op("w", "get_recent", 2.0, 2.1, 1),  # poll lags the push
    ]
    with pytest.raises(AssertionError, match="poll lagged push"):
        check_history(bad)
