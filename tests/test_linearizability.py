"""Linearizability-style checker for version-manager histories.

Concurrent APPEND / WRITE / GET_RECENT histories generated on the
virtual-time harness must admit a total order consistent with the
assigned versions (the paper's §4.3 total-ordering claim):

* versions form a contiguous total order 1..K,
* the version order is a linear extension of the real-time interval
  order — if update A responded before update B was invoked, then
  version(A) < version(B),
* GET_RECENT is monotone in real time (publication never goes
  backwards) and never returns a version from the future (one whose
  update had not even been invoked when the get responded),
* every returned recent version is fully readable (atomicity: the
  snapshot resolves completely).

Virtual timestamps come from ``Simulator.now()``, so the intervals are
exact — no wall-clock jitter — and every counterexample is replayable
from the seed.
"""

from dataclasses import dataclass
from typing import List

import pytest

from repro.core import BlobSeerService, Simulator, Wire


@dataclass(frozen=True)
class Op:
    client: str
    kind: str            # "append" | "write" | "get_recent"
    invoke: float
    respond: float
    result: int          # version assigned / version observed
    size: int = 0


def check_history(hist: List[Op]) -> None:
    updates = [op for op in hist if op.kind in ("append", "write")]
    gets = [op for op in hist if op.kind == "get_recent"]

    # total order: contiguous versions, one per update
    versions = sorted(op.result for op in updates)
    assert versions == list(range(1, len(updates) + 1)), versions

    # version order extends real-time precedence between updates
    for a in updates:
        for b in updates:
            if a.respond < b.invoke:
                assert a.result < b.result, (
                    f"{a.client} v{a.result} responded at {a.respond:.6f} "
                    f"before {b.client} v{b.result} invoked at {b.invoke:.6f} "
                    f"but got the later version"
                )

    # GET_RECENT: monotone in real time
    for a in gets:
        for b in gets:
            if a.respond < b.invoke:
                assert a.result <= b.result, (
                    f"recent version went backwards: {a.result} then {b.result}"
                )

    # GET_RECENT: never from the future
    assigned = {op.result: op for op in updates}
    for g in gets:
        if g.result > 0:
            u = assigned.get(g.result)
            assert u is not None, f"observed unassigned version {g.result}"
            assert u.invoke <= g.respond, (
                f"observed v{g.result} before its update was invoked"
            )


def _run_history(seed: int, n_updaters: int = 24, n_observers: int = 8,
                 ops_each: int = 3) -> List[Op]:
    sim = Simulator(seed=seed)
    svc = BlobSeerService(n_providers=6, n_meta_shards=3,
                          wire=Wire(clock=sim))
    setup = svc.client("setup")
    bid = setup.create(psize=64)
    setup.append(bid, b"\x00" * 128)  # v1 so early readers have something
    hist: List[Op] = []

    def updater(i):
        def prog():
            c = svc.client(f"u{i:03d}")
            for k in range(ops_each):
                inv = sim.now()
                if (i + k) % 3 == 0:
                    v = c.write(bid, bytes([i % 250 + 1]) * 64, 0)
                    kind = "write"
                else:
                    v = c.append(bid, bytes([i % 250 + 1]) * 64)
                    kind = "append"
                hist.append(Op(f"u{i:03d}", kind, inv, sim.now(), v, 64))
        return prog

    def observer(i):
        def prog():
            c = svc.client(f"o{i:03d}")
            for _ in range(ops_each):
                inv = sim.now()
                v = c.get_recent(bid)
                hist.append(Op(f"o{i:03d}", "get_recent", inv, sim.now(), v))
                if v:
                    # atomicity: the observed snapshot resolves completely
                    size = c.get_size(bid, v)
                    assert len(c.read(bid, v, 0, size)) == size
        return prog

    for i in range(n_updaters):
        sim.spawn(updater(i), name=f"u{i:03d}")
    for i in range(n_observers):
        sim.spawn(observer(i), name=f"o{i:03d}")
    sim.run()
    # drop the setup append from the contiguity check's expectations by
    # folding it in as an update that happened before everything
    hist.append(Op("setup", "append", -1.0, -0.5, 1, 128))
    return hist


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_concurrent_history_linearizes_to_version_order(seed):
    check_history(_run_history(seed))


def test_checker_rejects_precedence_violation():
    """The checker itself must catch a non-linearizable history."""
    bad = [
        Op("a", "append", 0.0, 1.0, 2),   # responded first, later version
        Op("b", "append", 2.0, 3.0, 1),   # invoked after a responded
    ]
    with pytest.raises(AssertionError, match="later version"):
        check_history(bad)


def test_checker_rejects_time_travelling_get_recent():
    bad = [
        Op("a", "append", 5.0, 6.0, 1),
        Op("o", "get_recent", 0.0, 0.5, 1),  # observed before invoked
    ]
    with pytest.raises(AssertionError, match="before its update"):
        check_history(bad)


def test_checker_rejects_nonmonotone_get_recent():
    bad = [
        Op("a", "append", 0.0, 0.1, 1),
        Op("b", "append", 0.0, 0.2, 2),
        Op("o1", "get_recent", 1.0, 1.1, 2),
        Op("o2", "get_recent", 2.0, 2.1, 1),  # goes backwards
    ]
    with pytest.raises(AssertionError, match="backwards"):
        check_history(bad)
