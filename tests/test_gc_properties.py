"""Seeded property tests: GC safety under random concurrent histories.

Hypothesis drives random interleavings of write / append / branch / pin
/ GC across 2-4 blobs on the deterministic Simulator.  The invariant:
nothing reachable from a kept or pinned version is ever swept — every
kept version reads back byte-identical to a flat oracle after each GC
round — and every retired version answers the typed ``RetiredVersion``.

The oracle replays the version manager's assigned update order (offset
and size from ``update_log``, payload from the per-op tags the clients
recorded), so it is exact for any interleaving the scheduler explores.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is unavailable
    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

from repro.core import BlobSeerService, RetiredVersion, Simulator, Wire
from repro.core.gc import collect_garbage


PSIZE = 16


def _payload(tag: int, size: int) -> bytes:
    return bytes([tag % 250 + 1]) * size


def _run_history(seed, n_blobs, n_clients, ops_per_client, keep_last):
    """Run a random concurrent history; returns everything the oracle
    needs: the service, the blob list and the per-version payload map."""
    sim = Simulator(seed=seed)
    svc = BlobSeerService(wire=Wire(clock=sim), n_providers=4, n_meta_shards=4)
    setup = svc.client("setup")
    payloads = {}       # (blob, version) -> payload bytes
    branches = []       # (parent, at, child)
    blobs = [setup.create(psize=PSIZE) for _ in range(n_blobs)]
    for j, bid in enumerate(blobs):
        setup.write(bid, _payload(200 + j, 3 * PSIZE), 0)
        payloads[(bid, 1)] = _payload(200 + j, 3 * PSIZE)
        setup.set_retention(bid, keep_last)

    def client_program(ci):
        def prog():
            c = svc.client(f"c{ci:02d}")
            rnd_tag = ci * ops_per_client * 7
            for k in range(ops_per_client):
                tag = rnd_tag + k
                bid = blobs[(ci + k) % len(blobs)]
                kind = (ci * 31 + k * 17 + seed) % 10
                try:
                    if kind < 4:                       # append
                        size = (tag % (3 * PSIZE)) + 1
                        v = c.append(bid, _payload(tag, size))
                        payloads[(bid, v)] = _payload(tag, size)
                    elif kind < 7:                     # overwrite (makes garbage)
                        bound = c.get_size(bid, c.get_recent(bid))
                        size = (tag % (2 * PSIZE)) + 1
                        off = (tag * 13) % max(bound, 1)
                        v = c.write(bid, _payload(tag, size), off)
                        payloads[(bid, v)] = _payload(tag, size)
                    elif kind == 7:                    # branch a live version
                        v = c.get_recent(bid)
                        if v > 0:
                            child = c.branch(bid, v)
                            blobs.append(child)
                            branches.append((bid, v, child))
                    elif kind == 8:                    # pin whatever is recent
                        v = c.get_recent(bid)
                        if v > 0:
                            c.pin(bid, v)              # held until the end
                    else:                              # a GC round, mid-traffic
                        collect_garbage(svc, client=f"gc-c{ci:02d}")
                except RetiredVersion:
                    # the recency pointer raced a concurrent GC round;
                    # a typed answer is the contract, never a KeyError
                    pass
            return None

        return prog

    for ci in range(n_clients):
        sim.spawn(client_program(ci), name=f"c{ci:02d}")
    sim.run()
    return svc, blobs, payloads, branches


def _oracle_contents(svc, blobs, payloads):
    """Flat per-version contents replayed from the assigned update order."""
    contents = {}  # (blob, version) -> bytes
    def fill(bid):
        if (bid, 0) in contents:
            return
        vm = svc.vm
        chain = vm.lineage(bid)
        base = chain[0][1]
        if len(chain) > 1:
            # versions <= base are the parent's snapshots, shared
            parent = chain[1][0]
            fill(parent)
            for v in range(0, base + 1):
                contents[(bid, v)] = contents[(parent, v)]
        else:
            contents[(bid, 0)] = b""
        v = base + 1
        while True:
            try:
                rec = vm.update_log(bid, v)
            except Exception:
                break
            prev = contents[(bid, v - 1)]
            buf = bytearray(max(len(prev), rec.offset + rec.size))
            buf[: len(prev)] = prev
            buf[rec.offset: rec.offset + rec.size] = payloads[(bid, v)]
            contents[(bid, v)] = bytes(buf)
            v += 1
    for bid in blobs:
        fill(bid)
    return contents


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n_blobs=st.integers(min_value=2, max_value=4),
    keep_last=st.integers(min_value=1, max_value=3),
)
def test_gc_never_sweeps_reachable_state(seed, n_blobs, keep_last):
    svc, blobs, payloads, _branches = _run_history(
        seed, n_blobs, n_clients=6, ops_per_client=4, keep_last=keep_last)
    # one final round from the driver (no reads in flight, free in
    # virtual time) so the checked state is post-sweep
    collect_garbage(svc, client="gc-final")
    contents = _oracle_contents(svc, blobs, payloads)

    reader = svc.client("verify")
    checked_kept = checked_retired = 0
    for bid in blobs:
        v = 1
        while (bid, v) in contents:
            want = contents[(bid, v)]
            owner = svc.vm.owner_of(bid, v)
            if v in svc.vm.retired_versions(owner):
                with pytest.raises(RetiredVersion):
                    reader.read(bid, v, 0, max(len(want), 1))
                checked_retired += 1
            else:
                assert reader.read(bid, v, 0, len(want)) == want, (
                    f"kept version {bid} v{v} corrupted by GC"
                )
                checked_kept += 1
            v += 1
    assert checked_kept > 0
    # pinned versions were never retired
    for lease in svc.vm.pins():
        owner = svc.vm.owner_of(lease.blob_id, lease.version)
        assert lease.version not in svc.vm.retired_versions(owner)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_gc_history_replays_identically(seed):
    """Same seed, same ops, GC in the schedule -> identical retired
    sets and identical kept bytes (the GC protocol is deterministic)."""
    a = _run_history(seed, n_blobs=2, n_clients=4, ops_per_client=4,
                     keep_last=2)
    b = _run_history(seed, n_blobs=2, n_clients=4, ops_per_client=4,
                     keep_last=2)
    svc_a, blobs_a = a[0], a[1]
    svc_b, blobs_b = b[0], b[1]
    assert len(blobs_a) == len(blobs_b)
    for bid_a, bid_b in zip(blobs_a, blobs_b):
        assert svc_a.vm.retired_versions(bid_a) == svc_b.vm.retired_versions(bid_b)
    assert svc_a.storage_report()["pages"] == svc_b.storage_report()["pages"]
