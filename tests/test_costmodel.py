"""Analytic cost model vs compiled HLO on scan-free probes.

Scan-free = every ``while`` trip count is 1 (single layer group, no grad
accumulation, sequences below the blockwise-attention threshold), where
XLA's once-per-body accounting is exact — validating the analytic
formulas that the roofline table then applies at full trip counts.
"""

import pytest

import jax

from repro.configs import get_config
from repro.configs.shapes import ShapeCell
from repro.launch.costmodel import avg_attended, cell_costs
from repro.launch.mesh import make_mesh
from repro.launch.specs import build_cell

BIG = dict(d_model=512, n_heads=8, n_kv_heads=8, d_head=64, d_ff=1536,
           vocab_size=8192)


def _ratio(arch, step, B, T, overrides, remat="none"):
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config(arch).reduced(**overrides)
    cell = ShapeCell(f"probe_{step}", step, T, B)
    prog = build_cell(cfg, cell, mesh, strategy="tp", remat_policy=remat, accum=1)
    comp = prog.jitted().lower(*prog.abstract_args).compile()
    from repro.launch.hlo import cost_analysis_dict
    hlo = cost_analysis_dict(comp).get("flops", 0.0)
    ana = cell_costs(cfg, cell, mesh, "tp", remat, 1).flops_per_device
    return ana / hlo


@pytest.mark.parametrize(
    "arch,step,B,T,overrides",
    [
        ("olmo-1b", "train", 2, 512, dict(n_layers=1, **BIG)),
        ("olmo-1b", "prefill", 2, 512, dict(n_layers=1, **BIG)),
        ("olmo-1b", "decode", 4, 2048, dict(n_layers=1, **BIG)),
        ("qwen3-32b", "train", 2, 512, dict(n_layers=1, **BIG)),
        ("olmoe-1b-7b", "train", 2, 512,
         dict(n_layers=1, n_experts=8, top_k=2, **BIG)),
        ("recurrentgemma-2b", "train", 2, 256,
         dict(n_layers=3, d_rnn=512, **BIG)),
        ("seamless-m4t-large-v2", "train", 2, 512,
         dict(n_layers=1, n_enc_layers=1, **BIG)),
    ],
)
def test_analytic_flops_close_to_hlo(arch, step, B, T, overrides):
    r = _ratio(arch, step, B, T, overrides)
    assert 0.85 < r < 1.2, f"{arch}/{step}: analytic/HLO = {r:.3f}"


def test_remat_full_multiplier_calibrated():
    r = _ratio("olmo-1b", "train", 2, 512, dict(n_layers=1, **BIG), remat="full")
    assert 0.85 < r < 1.2, r


def test_avg_attended():
    assert avg_attended(8, False, None) == 8
    assert avg_attended(8, True, None) == 4.5
    assert avg_attended(100, True, 10) == pytest.approx(
        (10 * 11 / 2 + 90 * 10) / 100)
    assert avg_attended(8, True, 100) == 4.5


def test_indivisible_heads_are_flagged_as_replicated():
    # qwen1.5: 40 heads on a 16-way model axis -> replicated compute note
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen1.5-32b")
    cell = ShapeCell("probe", "train", 128, 8)
    # fake a 16-way model axis via a mesh-shaped query: use spec guard
    from repro.distributed.partitioning import get_rules, spec_for
    import jax as _jax
    # direct check of the shard-factor logic instead (no 256 devices here)
    from repro.launch.costmodel import _div
    assert _div(cfg.n_heads, 16) == 1          # replicated
    assert _div(get_config("qwen3-32b").n_heads, 16) == 16
