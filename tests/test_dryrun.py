"""Dry-run machinery: runs in a subprocess so the 512-device XLA flag
never leaks into this test process (which must keep seeing 1 device)."""

import json
import os
import subprocess
import sys

import jax
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_this_process_sees_one_device():
    assert len(jax.devices()) == 1


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.load(open(tmp_path / "granite-moe-1b-a400m_decode_32k_single_tp_serve.json"))
    assert rec["status"] == "ok"
    assert rec["roofline"]["n_chips"] == 256
    assert rec["roofline"]["step_time_s"] > 0


def test_hlo_collective_parser():
    from repro.launch.hlo import collective_stats
    txt = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), replica_groups={}
  %ar = f32[512]{0} all-reduce(f32[512]{0} %y), to_apply=%add
  %cp.1 = (f32[8,8]{1,0}, f32[8,8]{1,0}) collective-permute(f32[8,8]{1,0} %z)
  %ard = f32[512]{0} all-reduce-done(f32[512]{0} %ar)
  %unrelated = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""
    st = collective_stats(txt)
    assert st.count_by_op["all-gather"] == 1
    assert st.bytes_by_op["all-gather"] == 16 * 1024 * 2
    assert st.count_by_op["all-reduce"] == 1
    assert st.bytes_by_op["collective-permute"] == 2 * 8 * 8 * 4
    assert st.total_count == 3


def test_roofline_terms():
    from repro.launch.hlo import Roofline, PEAK_FLOPS, HBM_BW, ICI_BW
    r = Roofline(flops=PEAK_FLOPS, hbm_bytes=HBM_BW / 2, collective_bytes=ICI_BW / 4,
                 n_chips=256, model_flops=PEAK_FLOPS * 256 * 0.5)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.mfu_bound == pytest.approx(0.5)
