"""Unit + property tests for the page/tree-shape algebra."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip when hypothesis is unavailable
    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda fn: fn

from repro.core import pages as P


def test_next_pow2():
    assert [P.next_pow2(x) for x in [0, 1, 2, 3, 4, 5, 8, 9]] == [1, 1, 2, 4, 4, 8, 8, 16]


def test_pages_spanned():
    assert P.pages_spanned(0, 64, 16) == (0, 4)
    assert P.pages_spanned(10, 10, 16) == (0, 2)
    assert P.pages_spanned(16, 16, 16) == (1, 2)
    assert P.pages_spanned(0, 0, 16) == (0, 0)


def test_root_pages_for():
    assert P.root_pages_for(0, 16) == 1
    assert P.root_pages_for(1, 16) == 1
    assert P.root_pages_for(17, 16) == 2
    assert P.root_pages_for(65, 16) == 8


def test_node_parent_children_roundtrip():
    for off, size in [(0, 1), (1, 1), (2, 2), (4, 4), (6, 2)]:
        poff, psize, is_left = P.node_parent(off, size)
        (lo, ls), (ro, rs) = P.node_children(poff, psize)
        child = (lo, ls) if is_left else (ro, rs)
        assert child == (off, size)


@given(
    p0=st.integers(0, 200),
    length=st.integers(1, 100),
    root_exp=st.integers(0, 9),
)
@settings(max_examples=200, deadline=None)
def test_created_nodes_are_exactly_intersecting(p0, length, root_exp):
    root = 1 << root_exp
    p1 = p0 + length
    if p1 > root:
        p0, p1 = p0 % root, min(p0 % root + length, root)
        if p0 >= p1:
            return
    ext = P.UpdateExtent(p0=p0, p1=p1, root_pages=root)
    created = set(P.iter_created_nodes(ext))
    # every created node intersects the range; the root is created
    for off, size in created:
        assert P.intersects(off, off + size, p0, p1)
    assert (0, root) in created
    # exhaustive check against the full binary tree
    full = set()
    size = 1
    while size <= root:
        for off in range(0, root, size):
            if P.intersects(off, off + size, p0, p1):
                full.add((off, size))
        size *= 2
    assert created == full


def test_fresh_page_ids_unique():
    ids = {P.fresh_page_id() for _ in range(1000)}
    assert len(ids) == 1000
