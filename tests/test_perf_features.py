"""Correctness of the §Perf optimization features.

Every optimization must be loss-preserving: MoE regrouping, ZeRO-2
hoisting, batched metadata puts, the client node cache and the
uneven-sharding rules all get equivalence or semantics tests here.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import BlobSeerService
from repro.distributed import partitioning as PT
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainStepBuilder


def test_moe_grouping_preserves_loss_statistics():
    """Regrouped dispatch must route identically when capacity is ample."""
    import dataclasses
    base = get_config("olmoe-1b-7b").reduced(n_experts=4, top_k=2)
    cfg_g = dataclasses.replace(base, moe_group=8, capacity_factor=4.0)
    cfg_n = dataclasses.replace(base, capacity_factor=4.0)
    m_g, m_n = build_model(cfg_g), build_model(cfg_n)
    params, _ = m_n.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, base.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, base.vocab_size),
    }
    l_n, _ = m_n.loss_fn(params, batch)
    l_g, _ = m_g.loss_fn(params, batch)
    # ample capacity -> same tokens reach the same experts -> same loss
    np.testing.assert_allclose(float(l_n), float(l_g), rtol=1e-4)


def test_zero2_step_matches_zero3():
    """ZeRO-2 hoisting is a scheduling change: params after one step
    must match the plain fsdp step bitwise-closely."""
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100, clip_norm=None)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    outs = {}
    for z2 in (False, True):
        b = TrainStepBuilder(model, mesh, strategy="tp_fsdp", opt=opt,
                             remat_policy="none", accum=2, zero2=z2)
        state = b.init_state(jax.random.PRNGKey(0))
        ap, ax = model.abstract()
        step = b.jit_train_step(ap, ax, jax.eval_shape(lambda: batch))
        state, m = step(state, batch)
        outs[z2] = (state["params"], float(m["loss"]))
    assert outs[False][1] == pytest.approx(outs[True][1], rel=1e-6)
    for a, b_ in zip(jax.tree.leaves(outs[False][0]), jax.tree.leaves(outs[True][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-5, atol=1e-7)


def test_put_many_equivalent_to_puts(service):
    dht = service.dht
    items = [((f"blob", 1, i, 1), {"pid": i}) for i in range(20)]
    dht.put_many(items, peer="c")
    for k, v in items:
        assert dht.get(k) == v
    # idempotent re-put of identical values (replica re-send semantics)
    dht.put_many(items, peer="c")
    with pytest.raises(ValueError):
        dht.put(items[0][0], {"pid": 999})


def test_node_cache_hits_and_correctness(service):
    c = service.client()
    bid = c.create(psize=16)
    v = c.write(bid, b"z" * 256, 0)
    c.read(bid, v, 0, 256)
    before = c.dht.misses
    c.read(bid, v, 0, 256)   # fully cached descent
    assert c.dht.misses == before
    assert c.dht.hits > 0
    # another client (cold cache) still reads correctly
    c2 = service.client()
    assert c2.read(bid, v, 10, 30) == b"z" * 30


def test_uneven_rules_shard_indivisible_dims():
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = PT.get_rules("tp_fsdp_uneven")
    assert rules.get(PT.UNEVEN_FLAG)
    spec = PT.spec_for(mesh, rules, ("embed", "q_heads", "head"), (64, 40, 128))
    # model axis size 1 here; semantic check is on the flag path:
    rules16 = PT.get_rules("tp_fsdp")
    assert PT.UNEVEN_FLAG not in rules16


def test_zero2_strategy_suffix_parsing():
    r1 = PT.get_rules("tp_fsdp_zero2")
    r2 = PT.get_rules("tp_fsdp")
    r1.pop(PT.UNEVEN_FLAG, None)
    assert r1 == r2
    r3 = PT.get_rules("tp_fsdp_zero2_uneven")
    assert r3.get(PT.UNEVEN_FLAG)


def test_dp_fsdp_ruleset_pure_dp():
    rules = PT.get_rules("dp_fsdp")
    assert rules["batch"] == ("pod", "data", "model")
    assert rules["q_heads"] is None and rules["mlp"] is None
    assert rules["embed"] == ("pod", "data", "model")


def test_costmodel_moe_group_lowers_dispatch():
    import dataclasses
    from repro.configs.shapes import SHAPES
    from repro.launch.costmodel import cell_costs
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("granite-moe-1b-a400m")
    cell = SHAPES["train_4k"]
    base = cell_costs(cfg, cell, mesh, "tp_fsdp", "full", 8)
    grouped = cell_costs(dataclasses.replace(cfg, moe_group=512), cell, mesh,
                         "tp_fsdp", "full", 8)
    assert grouped.breakdown["moe_dispatch"] < base.breakdown["moe_dispatch"] / 6
    assert grouped.breakdown["moe_experts"] == pytest.approx(
        base.breakdown["moe_experts"], rel=0.1)


def test_costmodel_zero2_cuts_collectives():
    from repro.configs.shapes import SHAPES
    from repro.launch.costmodel import cell_costs
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen3-32b")
    cell = SHAPES["train_4k"]
    # single-device mesh: dp=1 -> no fsdp collectives either way; check
    # the accounting on a fake 256-chip context via the formulas instead
    c3 = cell_costs(cfg, cell, mesh, "tp_fsdp", "full", 16)
    c2 = cell_costs(cfg, cell, mesh, "tp_fsdp_zero2", "full", 16)
    assert c2.collective_bytes_per_device <= c3.collective_bytes_per_device
