"""End-to-end: train, kill, restart, resume bit-identically."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import BlobCheckpointer
from repro.configs import get_config
from repro.core import BlobSeerService
from repro.data import ByteTokenizer, CorpusWriter, ShardedReader
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainStepBuilder

STEPS = 16
CKPT_AT = 8


def _setup(svc):
    c = svc.client("trainer")
    tok = ByteTokenizer()
    w = CorpusWriter(c, psize=4096)
    rng = np.random.default_rng(0)
    for i in range(60):
        n = int(rng.integers(40, 120))
        w.append_tokens(tok.encode(f"doc {i}: " + " ".join(
            f"w{int(rng.integers(0, 40))}" for _ in range(n))))
    cfg = get_config("olmo-1b").reduced(vocab_size=tok.vocab_size + 1)
    model = build_model(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    builder = TrainStepBuilder(
        model, mesh, strategy="tp",
        opt=AdamWConfig(lr=1e-3, warmup_steps=4, total_steps=STEPS),
        remat_policy="none",
    )
    ap, ax = model.abstract()
    batch_abs = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    step = builder.jit_train_step(ap, ax, batch_abs)
    return c, w, model, builder, step


def _run(svc, c, w, builder, step, ckpt, state, reader, lo, hi, losses):
    for s in range(lo, hi):
        tokens, labels = reader.next_batch()
        state, m = step(state, {"tokens": jnp.asarray(tokens),
                                "labels": jnp.asarray(labels)})
        losses.append(float(m["loss"]))
        if s + 1 == CKPT_AT:
            ckpt.save(state, step=s + 1, extra={"reader": reader.state_dict()})
    return state


def test_kill_restart_resumes_bit_identically():
    # ---- uninterrupted reference run ----
    svc = BlobSeerService(n_providers=4, n_meta_shards=2)
    c, w, model, builder, step = _setup(svc)
    ckpt = BlobCheckpointer(c, psize=4096, header_pages=32)
    state = builder.init_state(jax.random.PRNGKey(0))
    reader = ShardedReader(c, w.blob_id, batch=4, seq_len=32)
    ref_losses = []
    state = _run(svc, c, w, builder, step, ckpt, state, reader, 0, STEPS, ref_losses)
    ref_final = jax.tree.leaves(state["params"])[0]

    # ---- interrupted run: train to CKPT_AT, "crash", resume ----
    svc2 = BlobSeerService(n_providers=4, n_meta_shards=2)
    c2, w2, model2, builder2, step2 = _setup(svc2)
    ckpt2 = BlobCheckpointer(c2, psize=4096, header_pages=32)
    state2 = builder2.init_state(jax.random.PRNGKey(0))
    reader2 = ShardedReader(c2, w2.blob_id, batch=4, seq_len=32)
    losses2 = []
    state2 = _run(svc2, c2, w2, builder2, step2, ckpt2, state2, reader2,
                  0, CKPT_AT, losses2)
    del state2, reader2  # crash: in-memory training state lost

    state_abs = jax.eval_shape(lambda r: builder2.init_state(r), jax.random.PRNGKey(0))
    restored, mani = ckpt2.restore(state_abs, with_manifest=True)
    state3 = jax.tree.map(jnp.asarray, restored)
    assert mani["step"] == CKPT_AT
    reader3 = ShardedReader(c2, w2.blob_id, batch=4, seq_len=32,
                            state=mani["extra"]["reader"])
    state3 = _run(svc2, c2, w2, builder2, step2, ckpt2, state3, reader3,
                  CKPT_AT, STEPS, losses2)

    # identical loss trajectory + identical final params
    np.testing.assert_allclose(losses2, ref_losses, rtol=1e-6)
    final2 = jax.tree.leaves(state3["params"])[0]
    np.testing.assert_array_equal(np.asarray(ref_final), np.asarray(final2))


def test_generation_runs():
    from repro.launch.serve import generate
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    prompts = [np.asarray([1, 2, 3, 4], np.int32)] * 2
    outs = generate(model, params, prompts, max_new=6, max_len=16)
    assert all(len(o) == 10 for o in outs)
