"""Quickstart: the BlobSeer primitives in two minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import BlobSeerService


def main() -> None:
    # one deployment: version manager + 8 data providers + 4 metadata shards
    svc = BlobSeerService(n_providers=8, n_meta_shards=4, data_replication=2)
    client = svc.client("alice")

    # CREATE a blob (64-byte pages for demonstration)
    blob = client.create(psize=64)
    print(f"created {blob}; snapshot 0 is the empty blob")

    # WRITE / APPEND create new snapshot versions, never overwrite
    v1 = client.write(blob, b"the quick brown fox jumps over the lazy dog" * 4, 0)
    v2 = client.append(blob, b" -- and then some more data arrives" * 3)
    v3 = client.write(blob, b"JUMPED", 20)
    print(f"writes published versions {v1}, {v2}, {v3}")
    print(f"sizes: v1={client.get_size(blob, v1)} v2={client.get_size(blob, v2)} "
          f"v3={client.get_size(blob, v3)}")

    # every version stays readable (copy-on-write pages)
    print("v1[16:26] =", client.read(blob, v1, 16, 10))
    print("v3[16:26] =", client.read(blob, v3, 16, 10))

    # GET_RECENT + SYNC: read-your-writes
    recent = client.get_recent(blob)
    client.sync(blob, recent)
    print("recent =", recent)

    # BRANCH: fork history at v1; both lineages evolve independently
    fork = client.branch(blob, v1)
    vf = client.append(fork, b" [fork diverges here]")
    print("fork  :", client.read(fork, vf, 160, client.get_size(fork, vf) - 160))
    print("trunk :", client.read(blob, v3, 160, client.get_size(blob, v3) - 160))

    # a second client sees the same published state (atomicity)
    bob = svc.client("bob")
    print("bob reads v3[20:26] =", bob.read(blob, v3, 20, 6))

    # immutability-aware caching: bob's re-read of alice's range is
    # served by the shared page cache — zero provider RPCs
    svc.reset_rpc_counters()
    bob.read(blob, v3, 16, 10)
    rep = svc.rpc_report()
    print(f"cached re-read: provider_read_pages={rep['provider_read_pages']} "
          f"page_cache_hits={rep['page_cache_hits']}")
    assert rep["provider_read_pages"] == 0, "expected a pure cache hit"

    # storage accounting: versions share all unmodified pages
    print("storage report:", svc.storage_report())


if __name__ == "__main__":
    main()
