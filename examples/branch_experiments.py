"""Cheap branching for training experiments (paper §2.1 BRANCH).

Train a base model, then fork the checkpoint lineage at an intermediate
step into two branches with different learning rates — zero bytes are
copied at fork time (copy-on-write snapshots).  Both branches and the
trunk remain fully readable afterwards.

    PYTHONPATH=src python examples/branch_experiments.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import BlobCheckpointer
from repro.configs import get_config
from repro.core import BlobSeerService
from repro.data import ByteTokenizer, CorpusWriter, ShardedReader
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainStepBuilder

BASE_STEPS, BRANCH_STEPS = 40, 40


def main() -> None:
    svc = BlobSeerService(n_providers=6, n_meta_shards=4)
    client = svc.client("exp")
    tok = ByteTokenizer()
    writer = CorpusWriter(client, psize=16 * 1024)
    rng = np.random.default_rng(1)
    for i in range(200):
        writer.append_tokens(tok.encode(
            " ".join(f"w{int(rng.integers(0, 50))}" for _ in range(80))))

    cfg = get_config("olmo-1b").reduced(vocab_size=tok.vocab_size + 1)
    model = build_model(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    ap_, ax = model.abstract()
    batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}

    def make_step(lr, total):
        b = TrainStepBuilder(model, mesh, strategy="tp",
                             opt=AdamWConfig(lr=lr, warmup_steps=5,
                                             total_steps=total),
                             remat_policy="none")
        return b, b.jit_train_step(ap_, ax, batch_abs)

    builder, step_fn = make_step(1e-3, BASE_STEPS + BRANCH_STEPS)
    state = builder.init_state(jax.random.PRNGKey(0))
    reader = ShardedReader(client, writer.blob_id, batch=8, seq_len=32)
    ckpt = BlobCheckpointer(client, psize=16 * 1024, header_pages=16)

    def run(state, reader, steps, step_fn, label):
        losses = []
        for _ in range(steps):
            t, l = reader.next_batch()
            state, m = step_fn(state, {"tokens": jnp.asarray(t),
                                       "labels": jnp.asarray(l)})
            losses.append(float(m["loss"]))
        print(f"[{label}] loss {losses[0]:.3f} -> {losses[-1]:.3f}")
        return state

    # ---- trunk ----
    state = run(state, reader, BASE_STEPS, step_fn, "trunk")
    st = ckpt.save(state, step=BASE_STEPS, extra={"reader": reader.state_dict()})
    print(f"trunk checkpoint v{st.version} ({st.pages_total} pages)")

    # ---- two branches, forked with zero copying ----
    state_abs = jax.eval_shape(lambda r: builder.init_state(r),
                               jax.random.PRNGKey(0))
    results = {}
    for name, lr in [("branch-lowlr", 3e-4), ("branch-highlr", 3e-3)]:
        bck = ckpt.branch(st.version)          # O(1) fork
        restored, mani = bck.restore(state_abs, with_manifest=True)
        bstate = jax.tree.map(jnp.asarray, restored)
        breader = ShardedReader(client, writer.blob_id, batch=8, seq_len=32,
                                state=mani["extra"]["reader"])
        _, bstep = make_step(lr, BASE_STEPS + BRANCH_STEPS)
        bstate = run(bstate, breader, BRANCH_STEPS, bstep, name)
        bst = bck.save(bstate, step=BASE_STEPS + BRANCH_STEPS)
        results[name] = (bck, bst)
        print(f"{name}: saved v{bst.version}, "
              f"{bst.pages_written}/{bst.pages_total} pages written "
              f"(rest shared with trunk)")

    # trunk checkpoint is still intact and readable
    trunk = ckpt.restore(state_abs, version=st.version)
    print("trunk restore after branching: OK,",
          int(sum(np.prod(x.shape) for x in jax.tree.leaves(trunk))), "elements")
    print("storage report:", svc.storage_report())


if __name__ == "__main__":
    main()
