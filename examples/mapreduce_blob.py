"""The paper's §2.2 usage scenario, end to end.

A "digital processing company": many ingestion sites APPEND picture
records to one huge blob concurrently; at intervals, a fleet of map
workers READ disjoint parts of a *published* snapshot, extract (camera
type, contrast) pairs, and a reduce step aggregates average contrast per
camera — while ingestion keeps appending to later versions.  One worker
also WRITEs a processed picture back in place (new version, old
snapshot untouched), the paper's overwrite-during-map case.

    PYTHONPATH=src python examples/mapreduce_blob.py
"""

import json
import struct
import sys
import threading
from collections import defaultdict

sys.path.insert(0, "src")

import numpy as np

from repro.core import BlobSeerService

RECORD = 256  # fixed-size "picture": 16B header + pixels
CAMERAS = ["nikon-d3", "canon-a1", "pixel-9", "iphone-17"]


def make_record(rng, cam_id: int) -> bytes:
    pixels = rng.integers(0, 256, RECORD - 16, dtype=np.uint8)
    # header: magic "1CIP", camera id, reserved
    hdr = struct.pack("<IIII", 0x50494331, cam_id, 0, 0)
    return hdr + pixels.tobytes()


def main() -> None:
    svc = BlobSeerService(n_providers=12, n_meta_shards=6)
    ingest_clients = [svc.client(f"site-{i}") for i in range(4)]
    blob = ingest_clients[0].create(psize=1024)

    # ---- phase 1: concurrent ingestion from 4 sites ----
    def site(i: int, n: int) -> None:
        rng = np.random.default_rng(i)
        for _ in range(n):
            cam = int(rng.integers(0, len(CAMERAS)))
            ingest_clients[i].append(blob, make_record(rng, cam))

    threads = [threading.Thread(target=site, args=(i, 40)) for i in range(4)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    c0 = ingest_clients[0]
    snapshot = c0.get_recent(blob)
    n_records = c0.get_size(blob, snapshot) // RECORD
    print(f"ingested {n_records} pictures -> snapshot v{snapshot}")

    # ---- phase 2: map over disjoint ranges of the pinned snapshot,
    #      while ingestion CONTINUES on later versions ----
    bg = threading.Thread(target=site, args=(0, 30))
    bg.start()

    n_workers = 6
    per = n_records // n_workers
    results = []

    def mapper(w: int) -> None:
        c = svc.client(f"worker-{w}")
        lo = w * per
        hi = n_records if w == n_workers - 1 else lo + per
        data = c.read(blob, snapshot, lo * RECORD, (hi - lo) * RECORD)
        out = defaultdict(list)
        for r in range(hi - lo):
            rec = data[r * RECORD : (r + 1) * RECORD]
            _, cam, _, _ = struct.unpack("<IIII", rec[:16])
            pix = np.frombuffer(rec[16:], dtype=np.uint8)
            out[cam].append(float(pix.std()))  # "contrast"
        results.append(out)
        if w == 0:
            # overwrite the first picture with its processed version:
            # a WRITE -> new snapshot; v{snapshot} is untouched
            c.write(blob, b"\x00" * RECORD, lo * RECORD)

    mts = [threading.Thread(target=mapper, args=(w,)) for w in range(n_workers)]
    [t.start() for t in mts]
    [t.join() for t in mts]
    bg.join()

    # ---- reduce ----
    agg = defaultdict(list)
    for out in results:
        for cam, vals in out.items():
            agg[cam].extend(vals)
    print("average contrast by camera type:")
    for cam, vals in sorted(agg.items()):
        print(f"  {CAMERAS[cam]:10s} n={len(vals):4d} contrast={np.mean(vals):.2f}")

    final = c0.get_recent(blob)
    print(f"snapshot read stayed pinned at v{snapshot}; blob is now at v{final} "
          f"({c0.get_size(blob, final) // RECORD} pictures)")
    # the pinned snapshot still returns the ORIGINAL first record
    first = c0.read(blob, snapshot, 0, 16)
    assert first[:4] == b"1CIP"[::-1] or first[:4] == struct.pack("<I", 0x50494331)
    print("pinned snapshot unchanged by the in-place processing write: OK")


if __name__ == "__main__":
    main()
