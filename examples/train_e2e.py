"""End-to-end training with BlobSeer data + checkpoint substrate,
including a mid-run crash + bit-identical resume.

Runs a reduced olmo-family model for a few hundred steps on CPU.  The
corpus is ingested through APPENDs; checkpoints are incremental COW
saves (watch the pages_written/pages_total ratio); at step 150 the
trainer "crashes" — all in-memory state dropped — and resumes from the
checkpoint lineage + journaled data cursor.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import BlobCheckpointer
from repro.configs import get_config
from repro.core import BlobSeerService
from repro.data import ByteTokenizer, CorpusWriter, ShardedReader
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainStepBuilder


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--crash-at", type=int, default=150)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    svc = BlobSeerService(n_providers=6, n_meta_shards=4)
    client = svc.client("trainer")
    tok = ByteTokenizer()

    # ---- ingest a synthetic corpus through the blob store ----
    writer = CorpusWriter(client, psize=16 * 1024)
    rng = np.random.default_rng(0)
    for i in range(400):
        n = int(rng.integers(30, 150))
        writer.append_tokens(tok.encode(
            f"sample {i}: " + " ".join(f"tok{int(rng.integers(0, 64))}"
                                       for _ in range(n))))
    print(f"corpus: {writer.n_tokens():,} tokens in blob {writer.blob_id}")

    # ---- ~10M-param model (olmo family, reduced) ----
    cfg = get_config("olmo-1b").reduced(
        d_model=192, n_layers=4, n_heads=6, n_kv_heads=6, d_head=32,
        d_ff=512, vocab_size=tok.vocab_size + 1)
    model = build_model(cfg)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(model.abstract()[0]))
    print(f"model: {cfg.name} reduced, {n_params:,} params")

    mesh = make_mesh((1, 1), ("data", "model"))
    builder = TrainStepBuilder(
        model, mesh, strategy="tp",
        opt=AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=args.steps),
        remat_policy="none")
    ap_, ax = model.abstract()
    batch_abs = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32)}
    step_fn = builder.jit_train_step(ap_, ax, batch_abs)

    ckpt = BlobCheckpointer(client, psize=16 * 1024, header_pages=16)
    state = builder.init_state(jax.random.PRNGKey(0))
    reader = ShardedReader(client, writer.blob_id, batch=args.batch,
                           seq_len=args.seq)

    def run(state, reader, lo, hi, label):
        t0 = time.time()
        for s in range(lo, hi):
            tokens, labels = reader.next_batch()
            state, m = step_fn(state, {"tokens": jnp.asarray(tokens),
                                       "labels": jnp.asarray(labels)})
            if s % 25 == 0 or s == hi - 1:
                print(f"[{label}] step {s:4d} loss {float(m['loss']):.4f}")
            if (s + 1) % args.ckpt_every == 0:
                st = ckpt.save(state, step=s + 1,
                               extra={"reader": reader.state_dict()})
                print(f"[{label}] ckpt v{st.version} step {st.step}: "
                      f"{st.pages_written}/{st.pages_total} pages "
                      f"({st.sharing_fraction:.0%} shared with previous)")
        print(f"[{label}] {hi - lo} steps in {time.time() - t0:.1f}s")
        return state

    state = run(state, reader, 0, args.crash_at, "run-1")
    print("\n*** simulated crash: dropping all in-memory training state ***\n")
    del state, reader

    # ---- resume: everything comes back from the blob store ----
    state_abs = jax.eval_shape(lambda r: builder.init_state(r),
                               jax.random.PRNGKey(0))
    restored, mani = ckpt.restore(state_abs, with_manifest=True)
    state = jax.tree.map(jnp.asarray, restored)
    ckpt.load_digest_cache()
    reader = ShardedReader(client, writer.blob_id, batch=args.batch,
                           seq_len=args.seq, state=mani["extra"]["reader"])
    print(f"resumed at step {mani['step']} from checkpoint v{mani and ckpt.client.get_recent(ckpt.blob_id)}")
    state = run(state, reader, mani["step"], args.steps, "run-2")

    # ---- inspect the checkpoint lineage ----
    print("\ncheckpoint lineage (version, step):", ckpt.steps())
    print("storage report:", svc.storage_report())


if __name__ == "__main__":
    main()
